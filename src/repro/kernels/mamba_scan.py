"""Mamba-1 selective-scan chunked Pallas TPU kernel.

Mamba-1's decay exp(Δ_t ⊙ A) is (d_inner, N)-shaped per step, so the Mamba-2
matmul re-blocking does not apply; the honest TPU mapping is a VPU kernel
that keeps the recurrent state resident in VMEM:

* The grid is (B, DI/bdi, T/L): chunks innermost, so the (bdi, N) f32 state
  persists in VMEM scratch for the whole sequence sweep of one channel block.
* Each grid step streams an (L, bdi) x/Δ tile and an (L, N) B/C tile
  HBM→VMEM, then runs the L recurrence steps on the VPU with zero HBM
  traffic for the state — the selective scan is memory-bound, and this
  tiling reads x/Δ/B/C exactly once (roofline-optimal bytes).
* Channel blocks (bdi = 512 default) keep state at 512×16×4 B = 32 KB,
  leaving VMEM room for double-buffered input tiles.

Validated against kernels.ref.mamba_scan_ref with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(
    x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, s0_ref, y_ref, sT_ref, s_scr, y_scr, *, L, n_chunks
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (L, bdi)
    dt = dt_ref[0].astype(jnp.float32)  # (L, bdi)
    bm = b_ref[0].astype(jnp.float32)  # (L, N)
    cm = c_ref[0].astype(jnp.float32)  # (L, N)
    A = a_ref[...].astype(jnp.float32)  # (bdi, N)
    D = d_ref[...].astype(jnp.float32)  # (bdi,)

    def step(t, h):
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]  # (bdi,)
        dtt = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]
        bt = jax.lax.dynamic_slice_in_dim(bm, t, 1, 0)[0]  # (N,)
        ct = jax.lax.dynamic_slice_in_dim(cm, t, 1, 0)[0]
        da = jnp.exp(dtt[:, None] * A)  # (bdi, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        yt = jnp.sum(h * ct[None, :], axis=1) + D * xt  # (bdi,)
        pl.store(y_scr, (pl.dslice(t, 1), slice(None)), yt[None])
        return h

    h = jax.lax.fori_loop(0, L, step, s_scr[...])
    s_scr[...] = h
    y_ref[0, ...] = y_scr[...].astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sT_ref[0, ...] = h.astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_di", "interpret"))
def mamba_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    C: jax.Array,
    D: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 128,
    block_di: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x, dt: (B,T,DI); A: (DI,N); Bm, C: (B,T,N); D: (DI,); state: (B,DI,N)."""
    B, T, DI = x.shape
    N = A.shape[1]
    L = min(chunk, T)
    assert T % L == 0, f"T={T} must be a multiple of chunk={L}"
    n_chunks = T // L
    bdi = min(block_di, DI)
    assert DI % bdi == 0, f"DI={DI} must be a multiple of block_di={bdi}"
    n_di = DI // bdi

    kernel = functools.partial(_mamba_kernel, L=L, n_chunks=n_chunks)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, n_di, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, bdi), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, L, bdi), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, L, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, L, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((bdi, N), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((bdi,), lambda b, di, ci: (di,)),
            pl.BlockSpec((1, bdi, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bdi), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, bdi, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, DI), x.dtype),
            jax.ShapeDtypeStruct((B, DI, N), state.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bdi, N), jnp.float32),
            pltpu.VMEM((L, bdi), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, Bm, C, A, D, state)
    return y, sT
