"""RWKV6 (Finch) WKV chunked-scan Pallas TPU kernel.

The recurrence  out_t = r_t·(S_t + diag(u) k_t v_tᵀ);  S_{t+1} = diag(w_t) S_t + k_t v_tᵀ
is re-blocked for the MXU instead of ported as a per-step GPU loop:

* The grid is (B, H, T/L): chunks are the innermost (sequential) dim, so the
  (K, V) f32 state lives in VMEM scratch across the whole sequence sweep.
* Within a chunk of L steps the recurrence is closed-form:
  an (L, L, K) pairwise-decay tensor (exp of log-space cumsum differences,
  always ≤ 1 so f32-safe) turns the intra-chunk part into two dense matmuls
  (L×L)·(L×V) — MXU work — while the inter-chunk part is one (L×K)·(K×V)
  matmul against the carried state.
* L defaults to 32: the (L, L, K) tensor for K=64 is 512 KB f32 — it fits
  VMEM next to the r/k/v/w tiles and the state.

Validated against kernels.ref.rwkv6_scan_ref with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr, *, L: int, n_chunks: int
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = jnp.log(jnp.clip(w_ref[0, 0].astype(jnp.float32), 1e-38, 1.0))
    u = u_ref[0].astype(jnp.float32)  # (K,)
    s = s_scr[...]  # (K, V)

    cum = jnp.cumsum(lw, axis=0)  # inclusive
    # intra-chunk pairwise decays: exp(cum_{t-1} - cum_s), strict s < t, always <= 1
    dmat = (cum - lw)[:, None, :] - cum[None, :, :]  # (L, L, K)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (L, L), 1
    )
    dmat = jnp.where(tri[:, :, None], dmat, NEG_INF)
    att = jnp.sum(r[:, None, :] * jnp.exp(dmat) * k[None, :, :], axis=-1)  # (L, L)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (L,) u-bonus at s == t
    eye = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    ).astype(jnp.float32)
    att = att + diag[:, None] * eye
    intra = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    dec = jnp.exp(cum - lw)  # prior-state decay at step t: exp(cum_{t-1})
    inter = jax.lax.dot_general(
        r * dec, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0, ...] = (intra + inter).astype(o_ref.dtype)
    # carry: S' = exp(cum_{L-1}) ⊙ S + Σ_s exp(cum_{L-1} - cum_s) k_s v_sᵀ
    dend = jnp.exp(cum[-1][None, :] - cum)  # (L, K)
    s_scr[...] = jnp.exp(cum[-1])[:, None] * s + jax.lax.dot_general(
        k * dend, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sT_ref[0, 0, ...] = s_scr[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,T,H,K); u: (H,K); state: (B,H,K,V) -> (out (B,T,H,V), state)."""
    B, T, H, K = r.shape
    V = state.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, f"T={T} must be a multiple of chunk={L}"
    n_chunks = T // L
    rt, kt, vt, wt = (a.transpose(0, 2, 1, 3) for a in (r, k, v, w))  # (B,H,T,K)

    kernel = functools.partial(_rwkv6_kernel, L=L, n_chunks=n_chunks)
    out, sT = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, L, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, K), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, V), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return out.transpose(0, 2, 1, 3), sT
