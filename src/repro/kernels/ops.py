"""Dispatch layer: one public op per hot-spot, backend chosen by ``impl``.

``impl='auto'`` picks the Pallas kernel on real TPU and the pure-jnp
chunked/production path elsewhere (CPU container, and the multi-pod dry-run —
Pallas→Mosaic only lowers for TPU targets, while the chunked jnp paths lower
everywhere with equivalent FLOPs/bytes, keeping the roofline honest).

``impl='pallas'`` forces the kernel (with interpret=True off-TPU) — used by
the per-kernel allclose sweeps.  ``impl='ref'`` forces the naive oracle.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Any, Iterator, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.dispatch.profiles import encode_config
from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.mamba_scan import mamba_scan as _mamba_pallas
from repro.kernels.moe_gmm import gmm as _gmm_pallas
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6_pallas

# Global default, overridable for tests/benchmarks.
_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "auto")


def set_default_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "pallas", "ref", "chunked")
    _IMPL = impl


# ---------------------------------------------------------------------------
# Tuned kernel configs (repro.tune)
#
# ``_TUNED[op][impl]`` is a kwargs dict overriding that entry point's
# block/tile/chunk knobs.  The table is set by the tuner (sweep winners or a
# fleet-pulled cache) and takes precedence over hand-picked values — including
# ones callers pass explicitly, since replacing hand-picked configs with
# measured ones is the point.  Overrides apply at trace time, so they must be
# installed before jit compilation (the launch drivers tune before building
# the engine / train step).
# ---------------------------------------------------------------------------

_TUNED: dict[str, dict[str, dict[str, Any]]] = {}


def set_tuned_configs(table: Mapping[str, Mapping[str, Mapping[str, Any]]]) -> None:
    """Install tuned config overrides: ``{op: {impl: {param: value}}}``."""
    global _TUNED
    _TUNED = {
        op: {impl: dict(params) for impl, params in impls.items()}
        for op, impls in table.items()
    }


def clear_tuned_configs() -> None:
    global _TUNED
    _TUNED = {}


def tuned_overrides(op: str, impl: str) -> dict[str, Any]:
    return dict(_TUNED.get(op, {}).get(impl, {}))


def active_config(op: str, impl: str) -> str:
    """Canonical ``"k=v,..."`` encoding of the active overrides ("" = default)."""
    return encode_config(_TUNED.get(op, {}).get(impl, {}))


def config_tag(impl: str) -> str:
    """Cross-op summary of active overrides for one backend tier.

    Dispatch profile keys are per (op, backend); this folds every tuned op's
    config for ``impl`` into one stable tag (``"op:k=v;op2:k=v"``) so a
    coarse-grained dispatch target ("decode_step", "train_step") lands its
    samples in a bucket distinct from the untuned default.
    """
    parts = [
        f"{op}:{encode_config(impls[impl])}"
        for op, impls in sorted(_TUNED.items())
        if impls.get(impl)
    ]
    return ";".join(parts)


@contextmanager
def tuned_scope(
    table: Mapping[str, Mapping[str, Mapping[str, Any]]],
) -> Iterator[None]:
    """Temporarily install tuned overrides (sweep measurement, tests)."""
    global _TUNED
    prev = _TUNED
    set_tuned_configs(table)
    try:
        yield
    finally:
        _TUNED = prev


def _scan_chunk(op: str, impl: str, chunk: int, T: int) -> int:
    """Tuned chunk for a scan op, kept only when it divides the seq length.

    The chunked scans require ``T % min(chunk, T) == 0``; a winner swept on
    one workload shape must not crash another, so an indivisible override
    falls back to the caller's value.
    """
    tuned = _TUNED.get(op, {}).get(impl, {}).get("chunk")
    if tuned is not None and T % min(int(tuned), T) == 0:
        return int(tuned)
    return chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Optional[str]) -> str:
    impl = impl or _IMPL
    if impl == "auto":
        return "pallas" if _on_tpu() else "chunked"
    return impl


def _interp() -> bool:
    return not _on_tpu()


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    impl: Optional[str] = None,
) -> jax.Array:
    """Training/prefill attention.  Routes SWA to the O(S·window) local path."""
    impl = _resolve(impl)
    Sq, Sk = q.shape[1], k.shape[1]
    local_ok = (
        window is not None and causal and Sq == Sk and window * 2 < Sk and q_offset == 0
    )
    if impl == "pallas":
        return _fa_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, interpret=_interp(),
            **tuned_overrides("flash_attention", "pallas"),
        )
    if impl == "ref":
        return _ref.mha_ref(
            q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
        )
    if local_ok:
        return _ref.local_window_attention(q, k, v, window=window, softcap=softcap)
    return _ref.flash_attention_chunked(
        q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset,
        **tuned_overrides("flash_attention", "chunked"),
    )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos_ids: jax.Array,
    cur_pos: jax.Array,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _decode_pallas(
            q, k_cache, v_cache, pos_ids, cur_pos,
            window=window, softcap=softcap, interpret=_interp(),
            **tuned_overrides("decode_attention", "pallas"),
        )
    return _ref.decode_attention_ref(
        q, k_cache, v_cache, pos_ids, cur_pos, window=window, softcap=softcap
    )


def decode_attention_seq_sharded(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos_ids: jax.Array,
    cur_pos: jax.Array,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    seq_axes: tuple[str, ...] = ("model",),
    batch_axes: tuple[str, ...] = (),
) -> Optional[jax.Array]:
    """Split-KV decode over a sequence-sharded cache (flash-decoding combine).

    Left to sharding propagation, XLA may gather the seq-sharded K/V caches
    every decode step.  This shard_map computes rank-local partial softmax
    stats over each cache shard and combines (pmax/psum over ``seq_axes``)
    only the (B, H, D)-sized partials — the §Perf fix for collective-bound
    decode.  ``batch_axes``: mesh axes the batch dim is sharded over.

    Returns None when no ambient mesh / axes absent (caller falls back).
    """
    from jax.interpreters import pxla
    from jax.sharding import PartitionSpec as P

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or any(a not in mesh.shape for a in seq_axes):
        return None
    b_ax = tuple(a for a in batch_axes if a in mesh.shape) or None
    seq_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    bspec = P(b_ax) if b_ax else P()

    def local(q_, k_, v_, pos_, cur_):
        acc, m, l = _ref.decode_attention_ref(
            q_, k_, v_, pos_, cur_, window=window, softcap=softcap,
            return_stats=True,
        )
        m_g = jax.lax.pmax(m, seq_axes)
        scale = jnp.exp(m - m_g)
        acc = jax.lax.psum(acc * scale[..., None], seq_axes)
        l_g = jax.lax.psum(l * scale, seq_axes)
        out = acc / jnp.maximum(l_g, 1e-30)[..., None]
        B, Hkv, G, D = out.shape
        return out.reshape(B, Hkv * G, D).astype(q.dtype)

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(b_ax, None, None),            # q (B, Hq, D) replicated on seq axes
            P(b_ax, seq_spec, None, None),  # k cache: seq sharded
            P(b_ax, seq_spec, None, None),  # v cache
            P(b_ax, seq_spec),              # pos_ids
            bspec,                          # cur_pos
        ),
        out_specs=P(b_ax, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, pos_ids, cur_pos)


def gmm(x: jax.Array, w: jax.Array, *, impl: Optional[str] = None) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _gmm_pallas(
            x, w, interpret=_interp(), **tuned_overrides("moe_gmm", "pallas")
        )
    return _ref.gmm_ref(x, w)


def moe_ffn(
    x: jax.Array,
    w1: jax.Array,
    w3: jax.Array,
    w2: jax.Array,
    *,
    act: str = "silu",
    impl: Optional[str] = None,
) -> jax.Array:
    """Per-expert gated FFN over capacity buckets: act(x@w1) * (x@w3) @ w2."""
    impl = _resolve(impl)
    if impl == "pallas":
        tuned = tuned_overrides("moe_gmm", "pallas")
        h = _gmm_pallas(x, w1, epilogue=act, interpret=_interp(), **tuned)
        h = h * _gmm_pallas(x, w3, interpret=_interp(), **tuned)
        return _gmm_pallas(h, w2, interpret=_interp(), **tuned)
    return _ref.moe_ffn_ref(x, w1, w3, w2, act=act)


def rwkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 32,
    remat_chunks: bool = False,
    impl: Optional[str] = None,
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    chunk = _scan_chunk("rwkv6_scan", impl, chunk, r.shape[1])
    if impl == "pallas":
        return _rwkv6_pallas(r, k, v, w, u, state, chunk=chunk, interpret=_interp())
    if impl == "ref":
        return _ref.rwkv6_scan_ref(r, k, v, w, u, state)
    return _ref.rwkv6_scan_chunked(
        r, k, v, w, u, state, chunk=chunk, remat_chunks=remat_chunks
    )


def rwkv6_step(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array, state: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Single decode step: r,k,v,w: (B,H,K); state: (B,H,K,V)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    sf = state.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", rf, sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    s_new = wf[..., None] * sf + kv
    return out.astype(r.dtype), s_new.astype(state.dtype)


def mamba_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    C: jax.Array,
    D: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 128,
    remat_chunks: bool = False,
    impl: Optional[str] = None,
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    chunk = _scan_chunk("mamba_scan", impl, chunk, x.shape[1])
    if impl == "pallas":
        return _mamba_pallas(x, dt, A, Bm, C, D, state, chunk=chunk, interpret=_interp())
    if impl == "ref":
        return _ref.mamba_scan_ref(x, dt, A, Bm, C, D, state)
    return _ref.mamba_scan_chunked(
        x, dt, A, Bm, C, D, state, chunk=chunk, remat_chunks=remat_chunks
    )


def mamba_step(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    C: jax.Array,
    D: jax.Array,
    state: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single decode step: x, dt: (B,DI); Bm, C: (B,N); state: (B,DI,N)."""
    xf, dtf, bf, cf = (a.astype(jnp.float32) for a in (x, dt, Bm, C))
    Af, Df, hf = A.astype(jnp.float32), D.astype(jnp.float32), state.astype(jnp.float32)
    da = jnp.exp(dtf[..., None] * Af[None])
    h = da * hf + (dtf * xf)[..., None] * bf[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cf) + Df[None] * xf
    return y.astype(x.dtype), h.astype(state.dtype)


def rmsnorm(
    x: jax.Array, scale: jax.Array, *, eps: float = 1e-6, impl: Optional[str] = None
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _rmsnorm_pallas(x, scale, eps=eps, interpret=_interp())
    return _ref.rmsnorm_ref(x, scale, eps=eps)
