"""Flash attention with a custom VJP — the memory-roofline optimization.

Plain AD through the chunked-attention lax.scan stacks per-block softmax
residuals: the backward sees full (B, H, Sq, Sk) f32 tensors in HBM
(~64 GB/device/layer for the 4k-train cells — the №1 memory-term item found
by the dry-run analyzer).  The flash backward recomputes block scores from
(q, k, v, out, lse) instead: live memory O(Sq·block_k), HBM traffic O(S·D)
tiles rather than O(S²) residuals.

Matches kernels.ref.mha_ref forward AND backward (tests/test_kernels_vjp.py).
This is the TPU-production semantic of the flash_attention Pallas kernel;
the jnp implementation here is what the dry-run lowers (Pallas→Mosaic needs
a real TPU target), keeping the compiled HLO representative.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blocks(x: jax.Array, n: int, block: int, axis: int = 1):
    B = x.shape[0]
    shape = x.shape[:axis] + (n, block) + x.shape[axis + 1 :]
    return x.reshape(shape).swapaxes(0, axis)  # (n, B, block, ...)


def _mask(q_pos, k_pos, sk, causal, window):
    ok = k_pos[None, :] < sk
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return ok


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def flash_attention_fused(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_k: int = 512,
) -> jax.Array:
    out, _ = _fwd_impl(q, k, v, causal, window, softcap, scale, q_offset, block_k)
    return out


def _fwd_impl(q, k, v, causal, window, softcap, scale, q_offset, block_k):
    """Online-softmax forward; returns (out, lse)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale_ = 1.0 / math.sqrt(D) if scale is None else scale
    bk = min(block_k, Sk)
    n = -(-Sk // bk)
    pad = n * bk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kb, vb = _blocks(kp, n, bk), _blocks(vp, n, bk)
    qr = (q.reshape(B, Sq, Hkv, G, D) * scale_).astype(jnp.float32)
    q_pos = jnp.arange(Sq) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        kb_i, vb_i, start = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kb_i.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        ok = _mask(q_pos, start + jnp.arange(bk), Sk, causal, window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb_i.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    starts = jnp.arange(n) * bk
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, starts))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    lse = m + jnp.log(l)  # (B, Hkv, G, Sq)
    return out.astype(q.dtype), lse


def _fwd_rule(q, k, v, causal, window, softcap, scale, q_offset, block_k):
    out, lse = _fwd_impl(q, k, v, causal, window, softcap, scale, q_offset, block_k)
    return out, (q, k, v, out, lse)


def _bwd_rule(causal, window, softcap, scale, q_offset, block_k, res, dout):
    q, k, v, out, lse = res
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale_ = 1.0 / math.sqrt(D) if scale is None else scale
    bk = min(block_k, Sk)
    n = -(-Sk // bk)
    pad = n * bk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kb, vb = _blocks(kp, n, bk), _blocks(vp, n, bk)
    qr = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    do = dout.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    of = out.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    # delta_i = Σ_d dout_i · out_i  (flash-backward rowsum term)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", do, of)
    q_pos = jnp.arange(Sq) + q_offset

    def body(dq_acc, xs):
        kb_i, vb_i, start = xs
        kf, vf = kb_i.astype(jnp.float32), vb_i.astype(jnp.float32)
        s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qr * scale_, kf)
        s = jnp.tanh(s_raw / softcap) * softcap if softcap else s_raw
        ok = _mask(q_pos, start + jnp.arange(bk), Sk, causal, window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,Hkv,G,Sq,bk)
        dv_i = jnp.einsum("bhgqk,bqhgd->bkhd", p, do)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do, vf)
        ds = p * (dp - delta[..., None])
        if softcap:
            ds = ds * (1.0 - jnp.square(s / softcap))
        ds = jnp.where(ok[None, None, None], ds, 0.0)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf) * scale_
        dk_i = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qr) * scale_
        return dq_acc, (dk_i, dv_i)

    starts = jnp.arange(n) * bk
    dq0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (kb, vb, starts))
    dk = dk_b.swapaxes(0, 1).reshape(B, n * bk, Hkv, D)[:, :Sk]
    dv = dv_b.swapaxes(0, 1).reshape(B, n * bk, Hkv, D)[:, :Sk]
    return (
        dq.reshape(B, Sq, Hq, D).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


flash_attention_fused.defvjp(_fwd_rule, _bwd_rule)
