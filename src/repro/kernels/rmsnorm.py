"""RMSNorm Pallas TPU kernel.

Memory-bound fusion target: reads x once, writes y once (2·bytes(x) HBM
traffic — roofline-optimal).  Rows are tiled (block_rows, D) so the f32
mean-of-squares reduction happens entirely in VREGs; D (the model dim) stays
whole in VMEM, which every assigned architecture's d_model (≤ 8192) permits.

Validated against kernels.ref.rmsnorm_ref with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    scale = 1.0 + s_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: (..., D); scale: (D,).  (1 + scale) RMSNorm, f32 math."""
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    rows = xr.shape[0]
    br = min(block_rows, rows)
    pad = -rows % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:rows].reshape(orig_shape)
