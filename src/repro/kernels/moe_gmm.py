"""Grouped expert matmul (GMM) Pallas TPU kernel for MoE layers.

(E, C, D) @ (E, D, F) -> (E, C, F): one matmul per expert over its capacity
bucket.  TPU-native choices:
* The grid is (E, C/bc, F/bf, D/bd) with the contraction dim innermost, so a
  (bc, bf) f32 accumulator persists in VMEM scratch across the D sweep and the
  MXU sees back-to-back (bc×bd)·(bd×bf) tiles — bc/bf/bd default to 128/128/512
  (multiples of the 128-lane MXU edge).
* Expert weight tiles stream HBM→VMEM once per (ci, fi) pair; because experts
  are the outermost grid dim, weights for expert e are fully reused across its
  capacity rows before moving on (maximises VMEM reuse of the big operand).
* An optional fused epilogue applies the gated-FFN activation, saving one HBM
  round-trip of the (E, C, F) intermediate in the w1/w3 pass.

Validated against kernels.ref.gmm_ref with interpret=True.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d_blocks: int, epilogue: Optional[str]):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]  # (bc, bd)
    w = w_ref[0]  # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(di == n_d_blocks - 1)
    def _finish():
        acc = acc_scr[...]
        if epilogue == "silu":
            acc = acc * jax.nn.sigmoid(acc)
        elif epilogue == "gelu":
            acc = jax.nn.gelu(acc, approximate=True)
        o_ref[0, ...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "epilogue", "interpret")
)
def gmm(
    x: jax.Array,
    w: jax.Array,
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    epilogue: Optional[str] = None,
    interpret: bool = False,
) -> jax.Array:
    """x: (E, C, D); w: (E, D, F) -> (E, C, F) with f32 accumulation."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    pad_c, pad_f, pad_d = -C % block_c, -F % block_f, -D % block_d
    if pad_c or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, pad_d)))
    if pad_d or pad_f:
        w = jnp.pad(w, ((0, 0), (0, pad_d), (0, pad_f)))
    n_c, n_f, n_d = (C + pad_c) // block_c, (F + pad_f) // block_f, (D + pad_d) // block_d

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_d_blocks=n_d, epilogue=epilogue),
        grid=(E, n_c, n_f, n_d),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, n_c * block_c, n_f * block_f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :F]
