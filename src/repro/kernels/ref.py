"""Pure-jnp oracles + production fallback paths for every kernel.

Two tiers per op:

* ``*_ref`` — the simplest correct implementation (full materialisation).
  Ground truth for the Pallas kernels' allclose sweeps.  Test-scale only.
* ``*_chunked`` / ``*_local`` — the memory-bounded pure-jnp production path
  used on CPU and in the multi-pod dry-run (Pallas→Mosaic only lowers on real
  TPU).  Numerically equivalent (same f32 accumulation), FLOP/byte-equivalent
  to the Pallas kernels, so the roofline derived from the dry-run HLO is
  representative of the TPU execution.

Shape conventions:
  attention   q: (B, Sq, Hq, D);  k, v: (B, Skv, Hkv, D);  Hq % Hkv == 0
  decode      q: (B, Hq, D);      cache: (B, S, Hkv, D);   pos_ids: (B, S)
  gmm         x: (E, C, D);       w: (E, D, F)
  rwkv6       r,k,v,w: (B, T, H, K);  u: (H, K);  state: (B, H, K, V)
  mamba       x,dt: (B, T, DI);   B,C: (B, T, N);  A: (DI, N);  state: (B, DI, N)
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-finite: avoids NaN from (-inf) - (-inf) in fully-masked rows


def _softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x


# ---------------------------------------------------------------------------
# Attention — naive oracle
# ---------------------------------------------------------------------------


def mha_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full-materialisation attention oracle (GQA/causal/SWA/softcap)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    qr = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention — chunked flash (production fallback; blueprint of the kernel)
# ---------------------------------------------------------------------------


def flash_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_k: int = 512,
) -> jax.Array:
    """Online-softmax attention, lax.scan over KV blocks.  O(Sq·block_k) live."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    block_k = min(block_k, Sk)
    n_blocks = -(-Sk // block_k)
    pad = n_blocks * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, block_k, Hkv, D).swapaxes(0, 1)
    vb = v.reshape(B, n_blocks, block_k, Hkv, D).swapaxes(0, 1)
    qr = (q.reshape(B, Sq, Hkv, G, D) * scale).astype(jnp.float32)
    qpos = jnp.arange(Sq) + q_offset

    def body(carry, blk):
        m, l, acc = carry
        kb_i, vb_i, start = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kb_i.astype(jnp.float32))
        s = _softcap(s, softcap)
        kpos = start + jnp.arange(block_k)
        ok = kpos[None, :] < Sk
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            ok &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb_i.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    starts = jnp.arange(n_blocks) * block_k
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def local_window_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
) -> jax.Array:
    """Sliding-window attention by overlapping KV gather: O(Sq·window).

    Each q block of ``block_q`` rows attends to the KV slice
    [blk_start - window + 1, blk_start + block_q) — total width window+block_q.
    FLOPs scale with Sq·(window+block_q) instead of Sq².  Self-attention only
    (q and k aligned, causal).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Sq == Sk, "local attention is for aligned self-attention"
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    bq = block_q or min(max(window, 128), 1024)
    n_blocks = -(-Sq // bq)
    pad_q = n_blocks * bq - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    width = window - 1 + bq
    # Gather absolute kv index for (block, offset); clip and mask out-of-range.
    blk_start = jnp.arange(n_blocks) * bq
    kv_idx = blk_start[:, None] - (window - 1) + jnp.arange(width)[None, :]
    valid = (kv_idx >= 0) & (kv_idx < Sk)
    kv_idx_c = jnp.clip(kv_idx, 0, Sk - 1)
    kg = jnp.take(k, kv_idx_c.reshape(-1), axis=1).reshape(B, n_blocks, width, Hkv, D)
    vg = jnp.take(v, kv_idx_c.reshape(-1), axis=1).reshape(B, n_blocks, width, Hkv, D)
    qb = q.reshape(B, n_blocks, bq, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb, kg.astype(jnp.float32))
    s = _softcap(s, softcap)
    qpos = blk_start[:, None] + jnp.arange(bq)[None, :]  # (n, bq) absolute
    kpos = kv_idx  # (n, width) absolute
    ok = (
        valid[:, None, :]
        & (kpos[:, None, :] <= qpos[:, :, None])
        & (kpos[:, None, :] > qpos[:, :, None] - window)
    )
    s = jnp.where(ok[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, vg.astype(jnp.float32))
    out = out.reshape(B, n_blocks * bq, Hq, D)[:, :Sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one new token vs. a cache with explicit slot positions)
# ---------------------------------------------------------------------------


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos_ids: jax.Array,
    cur_pos: jax.Array,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    return_stats: bool = False,
):
    """Single-step attention against a (possibly ring-buffer) KV cache.

    pos_ids[b, s] is the absolute position stored in cache slot s (-1 = empty),
    which uniformly handles full caches and SWA ring buffers.  cur_pos: (B,).

    ``return_stats``: return the flash-decoding partials ``(acc, m, l)`` with
    out = acc / l — the combinable form for split-KV (sequence-sharded caches).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    qr = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache.astype(jnp.float32))
    s = _softcap(s, softcap)
    ok = (pos_ids >= 0) & (pos_ids <= cur_pos[:, None])
    if window is not None:
        ok &= pos_ids > cur_pos[:, None] - window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)  # (B, Hkv, G)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    if return_stats:
        return acc, m, l
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Grouped expert matmul (MoE)
# ---------------------------------------------------------------------------


def gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(E, C, D) @ (E, D, F) -> (E, C, F), f32 accumulation."""
    return jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def moe_ffn_ref(
    x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array, act: str = "silu"
) -> jax.Array:
    """Per-expert gated FFN: act(x@w1) * (x@w3) @ w2."""
    from repro.nn.core import ACTIVATIONS

    h = ACTIVATIONS[act](gmm_ref(x, w1).astype(jnp.float32)) * gmm_ref(x, w3).astype(
        jnp.float32
    )
    return gmm_ref(h.astype(x.dtype), w2)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) WKV scan
# ---------------------------------------------------------------------------


def rwkv6_scan_ref(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Naive per-step recurrence oracle.

      out_t = r_t · (S_t + diag(u) k_t v_tᵀ);   S_{t+1} = diag(w_t) S_t + k_t v_tᵀ

    r,k,v,w: (B,T,H,K); u: (H,K); state: (B,H,K,V).  Returns (out (B,T,H,V), state).
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf, sf = u.astype(jnp.float32), state.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = tuple(a.swapaxes(0, 1) for a in (rf, kf, vf, wf))  # (T,B,H,K)
    sf, out = jax.lax.scan(step, sf, xs)
    return out.swapaxes(0, 1).astype(r.dtype), sf.astype(state.dtype)


def rwkv6_scan_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 32,
    remat_chunks: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked matmul formulation (production path / Pallas blueprint).

    Within a chunk of L steps (log-space stable, pairwise decay tensor
    (L, L, K) stays in f32):

      out_t = r_t·(P_t ⊙ S₀) + Σ_{s<t} r_t·(D_{ts} ⊙ k_s) v_s + (r_t·(u ⊙ k_t)) v_t
      D_{ts} = exp(cum_t − cum_{s+1}) ≤ 1,   P_t = exp(cum_t),  cum = cumsum(log w)
    """
    B, T, H, K = r.shape
    L = min(chunk, T)
    assert T % L == 0, f"T={T} must be a multiple of chunk={L}"
    n = T // L
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-38, 1.0))
    uf, s0 = u.astype(jnp.float32), state.astype(jnp.float32)

    def chunk_body(s, xs):
        rc, kc, vc, lwc = xs  # each (B,L,H,K)
        cum = jnp.cumsum(lwc, axis=1)  # inclusive: cum_t = Σ_{i<=t} lw_i
        # Recurrence (matches the oracle): out_t reads S_t, then S_{t+1} = w_t S_t + k_t v_t.
        # kv_s's coefficient when read at t (s < t) is Π_{i=s+1}^{t-1} w_i
        #   = exp(cum_{t-1} - cum_s) = exp(cum_t - lw_t - cum_s)  ≤ 1.
        dmat = (cum - lwc)[:, :, None] - cum[:, None, :]  # (B,L,L,H,K): t=dim1, s=dim2
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)  # strict s < t
        dmat = jnp.where(tri[None, :, :, None, None], dmat, NEG_INF)
        att = jnp.einsum("bthk,btshk,bshk->bths", rc, jnp.exp(dmat), kc)
        diag = jnp.einsum("bthk,hk,bthk->bth", rc, uf, kc)  # u-bonus at s == t
        att = att + diag[..., None] * jnp.eye(L)[None, :, None, :]
        intra = jnp.einsum("bths,bshv->bthv", att, vc)
        # Prior-chunk state read at local t decays by Π_{i<t} w_i = exp(cum_{t-1}).
        dec = jnp.exp(cum - lwc)
        inter = jnp.einsum("bthk,bhkv->bthv", rc * dec, s)
        # Chunk-end state: S_L = exp(cum_{L-1}) ⊙ S₀ + Σ_s exp(cum_{L-1} - cum_s) k_s v_s.
        dend = jnp.exp(cum[:, -1:, :, :] - cum)  # (B,L,H,K)
        s = jnp.exp(cum[:, -1])[..., None] * s + jnp.einsum(
            "bshk,bshv->bhkv", kc * dend, vc
        )
        return s, intra + inter

    def reshape_c(a):
        return a.reshape(B, n, L, H, K).swapaxes(0, 1)

    xs = tuple(reshape_c(a) for a in (rf, kf, vf, lw))
    # remat_chunks (§Perf, mirrors the flash VJP): AD saves only (B, H, K, V)
    # chunk-boundary states, not the (L, L, K) pairwise tensors per chunk.
    body = (
        jax.checkpoint(chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
        if remat_chunks else chunk_body
    )
    s, out = jax.lax.scan(body, s0, xs)
    out = out.swapaxes(0, 1).reshape(B, T, H, K)
    return out.astype(r.dtype), s.astype(state.dtype)


# ---------------------------------------------------------------------------
# Mamba selective scan
# ---------------------------------------------------------------------------


def mamba_scan_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    C: jax.Array,
    D: jax.Array,
    state: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Naive selective-scan oracle.

      h_t = exp(dt_t ⊙ A) h_{t-1} + dt_t (B_t ⊗ x_t);  y_t = C_t·h_t + D ⊙ x_t

    x, dt: (B,T,DI); A: (DI,N); Bm, C: (B,T,N); D: (DI,); state: (B,DI,N).
    """
    xf, dtf, Bf, Cf = (a.astype(jnp.float32) for a in (x, dt, Bm, C))
    Af, Df, sf = A.astype(jnp.float32), D.astype(jnp.float32), state.astype(jnp.float32)

    def step(h, xs):
        xt, dtt, bt, ct = xs  # (B,DI) (B,DI) (B,N) (B,N)
        da = jnp.exp(dtt[..., None] * Af[None])  # (B,DI,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct) + Df[None] * xt
        return h, y

    xs = tuple(a.swapaxes(0, 1) for a in (xf, dtf, Bf, Cf))
    sf, y = jax.lax.scan(step, sf, xs)
    return y.swapaxes(0, 1).astype(x.dtype), sf.astype(state.dtype)


def mamba_scan_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    C: jax.Array,
    D: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 128,
    remat_chunks: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked scan: lax.scan over chunks × associative_scan within a chunk.

    Live memory is O(B·L·DI·N) per chunk instead of O(B·T·DI·N).
    """
    B, T, DI = x.shape
    N = A.shape[1]
    L = min(chunk, T)
    assert T % L == 0, f"T={T} must be a multiple of chunk={L}"
    n = T // L
    xf, dtf, Bf, Cf = (a.astype(jnp.float32) for a in (x, dt, Bm, C))
    Af, Df, s0 = A.astype(jnp.float32), D.astype(jnp.float32), state.astype(jnp.float32)

    def chunk_body(h0, xs):
        xc, dtc, bc, cc = xs  # (B,L,DI) (B,L,DI) (B,L,N) (B,L,N)
        a = jnp.exp(dtc[..., None] * Af[None, None])  # (B,L,DI,N)
        b = (dtc * xc)[..., None] * bc[:, :, None, :]  # (B,L,DI,N)
        # prepend carry as step 0 with a=1
        a_full = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b_full = jnp.concatenate([h0[:, None], b], axis=1)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        _, h = jax.lax.associative_scan(combine, (a_full, b_full), axis=1)
        h = h[:, 1:]  # (B,L,DI,N)
        y = jnp.einsum("bldn,bln->bld", h, cc) + Df[None, None] * xc
        return h[:, -1], y

    def reshape_c(a):
        return a.reshape((B, n, L) + a.shape[2:]).swapaxes(0, 1)

    xs = tuple(reshape_c(a) for a in (xf, dtf, Bf, Cf))
    # remat_chunks (§Perf, the Mamba analogue of the flash VJP): AD saves only
    # the (B, DI, N) chunk-boundary states, not (B, L, DI, N) per-step stacks.
    body = (
        jax.checkpoint(chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
        if remat_chunks else chunk_body
    )
    hT, y = jax.lax.scan(body, s0, xs)
    y = y.swapaxes(0, 1).reshape(B, T, DI)
    return y.astype(x.dtype), hT.astype(state.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )
