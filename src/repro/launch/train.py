"""Training driver.

Local smoke (1 device, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced --steps 20

Real sharded execution on N host devices (exercises the same pjit path as TPU):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \\
      --mesh 2x4 --steps 20 --batch 8

Fault-tolerance demo: --fail-at 7,17 injects node failures; the supervisor
restarts from the latest checkpoint and replays deterministically.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dispatch import DispatchConfig, Dispatcher, with_impl
from repro.distributed import sharding as shd
from repro.runtime.supervisor import FailureInjector, Supervisor, SupervisorConfig
from repro.trace import (
    Session,
    StreamingSession,
    TraceCollector,
    age_out_profiles,
    load_profile_stores,
)
from repro.training.step import (
    TrainConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
    train_state_axes,
)


def build_mesh(spec: str) -> Mesh:
    dims = tuple(int(x) for x in spec.split("x"))
    n = int(np.prod(dims))
    devs = jax.devices()
    if len(devs) < n:
        raise SystemExit(
            f"mesh {spec} needs {n} devices, have {len(devs)}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return Mesh(np.asarray(devs[:n]).reshape(dims), axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="e.g. 2x4 = data2 x model4")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", default="", help="comma list of steps to inject failures")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--dispatch", choices=("off", "static", "roofline", "profiled"), default="off",
        help="profile-guided kernel-backend placement per train step (repro.dispatch)",
    )
    ap.add_argument("--dispatch-backend", default="chunked",
                    help="backend pinned by --dispatch static")
    ap.add_argument("--tune", choices=("off", "cached", "sweep"), default="off",
                    help="kernel autotuning (repro.tune): cached applies "
                         "winners already in the profile store (e.g. fleet-"
                         "pulled) with zero sweep cost; sweep measures "
                         "missing design-space points first")
    ap.add_argument("--tune-ops", default=None, metavar="OP[,OP]",
                    help="restrict --tune sweep to these ops")
    ap.add_argument("--tune-mode", choices=("real", "interpret", "synthetic"),
                    default="interpret",
                    help="sweep measurement mode (synthetic = model-only, CI)")
    ap.add_argument("--tune-workers", type=int, default=0, metavar="N",
                    help="sweep worker processes (0 = in-process)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a repro.trace session snapshot of this run")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="stream events durably as rotated JSONL segments "
                         "(crash loses at most the open segment; recover with "
                         "`python -m repro.trace compact DIR`)")
    ap.add_argument("--trace-rotate", type=int, default=2048, metavar="N",
                    help="events per streaming segment before rotation+fsync")
    ap.add_argument("--trace-rotate-keep", type=int, default=None, metavar="N",
                    help="segment retention: delete the oldest closed segments "
                         "past N so --trace-dir stays bounded on long runs")
    ap.add_argument("--fleet", default=None, metavar="URL|DIR",
                    help="central profile service (repro.fleet): pull matching "
                         "profiles at startup, push measured deltas at "
                         "shutdown and every streaming rotation")
    ap.add_argument("--fleet-token", default=None, metavar="TOKEN",
                    help="bearer token for a --token-protected fleet daemon")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="trace ring-buffer capacity (events); evictions are counted")
    ap.add_argument("--profile-in", action="append", default=None, metavar="PATH",
                    help="warm-start dispatch profiles from a session/store JSON "
                         "(repeatable; multiple files are merged)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write the measured ProfileStore for the next run")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus /metrics on this port while the "
                         "run is live (0 picks a free port)")
    ap.add_argument("--trace-overhead-budget-pct", type=float, default=None,
                    metavar="PCT",
                    help="adaptive tracing: duty-cycle span capture to keep "
                         "self-measured record-path overhead under PCT%% "
                         "(0 = always-on: measure, never shed; default 5 "
                         "when --metrics-port is given)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="live device profiling: duty-cycled jax.profiler "
                         "capture windows dumped under DIR, parsed and merged "
                         "into the live trace under the overhead budget")
    ap.add_argument("--jax-profile-backend", default="auto",
                    choices=("auto", "jax", "synthetic"),
                    help="profiler backend: jax.profiler (auto/jax; degrades "
                         "gracefully without one) or the synthetic CI stub")
    ap.add_argument("--jax-profile-period-s", type=float, default=2.0,
                    metavar="S", help="device capture window period (on+off)")
    args = ap.parse_args()
    if args.fleet and args.dispatch == "off":
        # a fleet-less run would silently neither warm-start nor push
        ap.error("--fleet requires --dispatch (static|roofline|profiled)")
    if args.tune != "off" and args.dispatch == "off":
        # tune winners live in the dispatcher's profile store
        ap.error("--tune requires --dispatch (static|roofline|profiled)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    import dataclasses

    from repro.training import optim

    tcfg = TrainConfig(
        opt=optim.AdamWConfig(peak_lr=args.lr, warmup_steps=max(10, args.steps // 10),
                              total_steps=args.steps),
        microbatches=args.microbatches,
    )
    mesh = build_mesh(args.mesh)
    rules = shd.DEFAULT_RULES
    key = jax.random.PRNGKey(args.seed)

    with mesh:
        state_abs = abstract_train_state(cfg, tcfg)
        state_shd = shd.tree_shardings(train_state_axes(cfg), state_abs, rules.param, mesh)
        init_jit = jax.jit(
            lambda k: init_train_state(cfg, tcfg, k), out_shardings=state_shd
        )
        state = init_jit(key)
        step_fn = jax.jit(
            make_train_step(cfg, tcfg),
            in_shardings=(state_shd, None),
            out_shardings=(state_shd, None),
            donate_argnums=(0,),
        )
        dispatcher = None
        step_variants = None
        aged = []
        if args.dispatch != "off":
            store = load_profile_stores(args.profile_in) if args.profile_in else None
            dispatcher = Dispatcher(
                DispatchConfig(policy=args.dispatch, static_backend=args.dispatch_backend),
                store=store,
            )
            if args.profile_in:
                aged = age_out_profiles(dispatcher.store, dispatcher.chip.name)
            step_variants = {
                t.name: jax.jit(
                    with_impl(t.impl, make_train_step(cfg, tcfg)),
                    in_shardings=(state_shd, None),
                    out_shardings=(state_shd, None),
                    donate_argnums=(0,),
                )
                for t in dispatcher.registry.targets()
            }
        fleet_rec = pusher = None
        run_meta = {"driver": "train", "arch": cfg.name, "mesh": args.mesh,
                    "steps": args.steps}
        if args.fleet and dispatcher is not None:
            from repro.fleet import warm_start_from_fleet

            fleet_rec, pusher = warm_start_from_fleet(args.fleet, dispatcher,
                                                      token=args.fleet_token)
            # recorded in session/manifest metadata: push-profiles refuses to
            # re-push artifacts of runs that already fed a fleet live
            run_meta["fleet"] = args.fleet

        data = SyntheticLM(
            DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
        )

        def batch_fn(i):
            b = data.batch(i)
            return {k: jnp.asarray(v) for k, v in b.items()}

        log = TraceCollector(capacity=args.trace_capacity)
        if dispatcher is not None:
            dispatcher.log = log
        tune_rec = None
        if args.tune != "off" and dispatcher is not None:
            # after the fleet pull (pulled config points make sweep points
            # warm — a fed fleet means sweep_points == 0) and before the
            # first step traces the jitted variants (winners must be
            # installed first); sweep samples land in dispatcher.store, so
            # the pusher delta-pushes tuned winners like any measurement
            from repro.tune import driver_tune

            tune_rec = driver_tune(
                args.tune, dispatcher, log,
                ops_filter=args.tune_ops.split(",") if args.tune_ops else None,
                mode=args.tune_mode, workers=args.tune_workers,
            )
        from repro.metrics import (
            DEFAULT_BUDGET_PCT,
            AdaptiveController,
            MetricsPlane,
            serve_metrics,
        )

        plane = MetricsPlane(log)
        controller = mserver = None
        if (args.metrics_port is not None
                or args.trace_overhead_budget_pct is not None):
            budget = (DEFAULT_BUDGET_PCT
                      if args.trace_overhead_budget_pct is None
                      else args.trace_overhead_budget_pct)
            controller = AdaptiveController(log, plane.registry,
                                            budget_pct=budget).start()
        if args.metrics_port is not None:
            import sys

            mserver = serve_metrics(plane, port=args.metrics_port)
            print(f"metrics: {mserver.url}/metrics", file=sys.stderr)
        prof = None
        if args.jax_profile:
            from repro.trace.liveprof import LiveDeviceProfiler

            prof = LiveDeviceProfiler(
                log, args.jax_profile,
                registry=plane.registry,
                backend=args.jax_profile_backend,
                budget_pct=(DEFAULT_BUDGET_PCT
                            if args.trace_overhead_budget_pct is None
                            else args.trace_overhead_budget_pct),
                period_s=args.jax_profile_period_s,
            )
        stream = None
        if args.trace_dir:
            stream = StreamingSession(
                args.trace_dir,
                rotate_events=args.trace_rotate,
                max_segments=args.trace_rotate_keep,
                meta=run_meta,
                store_provider=(lambda: dispatcher.store) if dispatcher is not None else None,
                fleet_push=pusher.push if pusher is not None else None,
                metrics_provider=plane.snapshot,
                device_provider=prof.snapshot if prof is not None else None,
            ).attach(log)
        fail_at = tuple(int(s) for s in args.fail_at.split(",") if s)
        sup = Supervisor(
            SupervisorConfig(
                ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every,
                max_steps=args.steps,
            ),
            step_fn,
            batch_fn,
            state,
            state_shardings=state_shd,
            log=log,
            failures=FailureInjector(fail_at),
            dispatcher=dispatcher,
            step_variants=step_variants,
            stream=stream,
        )
        if prof is not None:
            prof.start()
        t0 = time.time()
        # root span: steps (and their checkpoint/dispatch children) nest
        # under the run in report --tree and the exporters
        with log.lifecycle("train_run", {"arch": cfg.name, "mesh": args.mesh}):
            out = sup.run()
        wall = time.time() - t0
        if prof is not None:
            prof.stop()  # force-closes the open window: short runs still merge

    losses = [float(m["loss"]) for m in out["metrics"]]
    tok_per_step = args.batch * args.seq
    rec = {
        "arch": cfg.name,
        "mesh": args.mesh,
        "steps": out["steps"],
        "restarts": out["restarts"],
        "stragglers": out["stragglers"],
        "first_loss": round(losses[0], 4),
        "last_loss": round(losses[-1], 4),
        "tokens_per_s": round(out["steps"] * tok_per_step / wall),
        "wall_s": round(wall, 1),
    }
    if dispatcher is not None:
        rec["dispatch"] = dispatcher.summary()
        rec["dispatch_events"] = len(log.events(kind="dispatch"))
        if args.profile_in:
            rec["profile_in"] = args.profile_in
            rec["profile_aged_out"] = len(aged)
    if tune_rec is not None:
        rec["tune"] = tune_rec
    if controller is not None:
        controller.stop()  # final overhead reading lands in the gauges
        rec["trace_controller"] = controller.snapshot()
    if prof is not None:
        rec["device_capture"] = prof.snapshot()
        run_meta["device_capture"] = rec["device_capture"]
    rec["metrics"] = plane.summary()
    trace_stats = log.stats()  # stats() resolves spans; compute once
    rec["trace"] = trace_stats
    if stream is not None:
        rec["trace_dir"] = stream.close(stats=trace_stats)
    if pusher is not None:
        final = pusher.push()  # remaining delta (no-op if a rotation covered it)
        fleet_rec["push"] = {"pushed_samples": pusher.pushed_samples}
        if "error" in final:
            fleet_rec["push"]["error"] = final["error"]
    if fleet_rec is not None:
        rec["fleet"] = fleet_rec
    if args.trace_out:
        sess = Session.capture(log, dispatcher=dispatcher,
                               meta={**run_meta, "metrics": plane.snapshot(),
                                     "drops": log.drop_counters()},
                               collector_stats=trace_stats)
        rec["trace_out"] = sess.save(args.trace_out)
    if args.profile_out and dispatcher is not None:
        doc = json.loads(dispatcher.store.to_json())
        if args.fleet:
            # marks the artifact as already fed to a fleet live, so
            # push-profiles refuses to double-count it later
            doc["fleet"] = args.fleet
        with open(args.profile_out, "w") as f:
            json.dump(doc, f, indent=1)
        rec["profile_out"] = args.profile_out
    print(json.dumps(rec), flush=True)
    if mserver is not None:
        mserver.stop()


if __name__ == "__main__":
    main()
