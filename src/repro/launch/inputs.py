"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the abstract batch for the shape's step
kind; ``step_signature`` bundles it with the abstract state/caches — the
complete ``.lower()`` argument list for the dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.activation_dtype)
    if shape.kind == "train":
        batch = {
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
        if cfg.frontend != "text":
            batch["frontend_embed"] = SDS((B, S, cfg.d_model), act)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.frontend != "text":
            batch["frontend_embed"] = SDS((B, S, cfg.d_model), act)
        return batch
    if shape.kind == "decode":
        batch = {
            "tokens": SDS((B,), jnp.int32),
            "cur_pos": SDS((B,), jnp.int32),
        }
        if cfg.frontend != "text":
            batch["frontend_embed"] = SDS((B, 1, cfg.d_model), act)
        return batch
    raise ValueError(shape.kind)


def abstract_decode_caches(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """Caches sized for the shape's context length (decode shapes only)."""
    assert shape.kind == "decode"
    return lm.abstract_caches(cfg, shape.global_batch, shape.seq_len)
