"""Mesh construction (functions only — importing never touches device state).

Production topology (TPU v5e): 16×16 = 256 chips per pod; the multi-pod mesh
adds a leading 'pod' axis over DCN.  'data' is the FSDP axis, 'model' the
TP/EP axis, 'pod' pure DP (parameters never shard across DCN).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — the dry-run "
            "sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh() -> Mesh:
    """1-device (data=1, model=1) mesh for CPU smoke tests."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
