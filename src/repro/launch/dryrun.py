"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each cell,
``jax.jit(step).lower(abstract args).compile()`` must succeed on the
production mesh, and the compiled artifact yields the roofline inputs
(cost_analysis FLOPs/bytes + collective operand bytes from the HLO text).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count on first init, so this precedes every other import.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, supports_shape
from repro.core.roofline import analyze_compiled, model_flops
from repro.distributed import sharding as shd
from repro.launch import inputs as inputs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.training.step import TrainConfig, abstract_train_state, make_train_step, train_state_axes


def _shardings(tree_axes, tree_abs, rules, mesh):
    return shd.tree_shardings(tree_axes, tree_abs, rules, mesh)


def _v_it1(cfg):
    import dataclasses

    return dataclasses.replace(
        cfg,
        fused_attention_vjp=True,
        pad_heads_to=16 if cfg.n_heads % 16 else 0,
        activation_constraints=True,
    )


def _v_it2(cfg):
    import dataclasses

    return dataclasses.replace(_v_it1(cfg), loss_table_replicated=True)


def _v_it3(cfg):
    import dataclasses

    # fewer/bigger CE chunks: the (replicated-on-data) unembed table is
    # re-read once per chunk — 8k chunks cut that traffic 8× while per-device
    # logits stay ~100 MB.
    return dataclasses.replace(_v_it2(cfg), loss_chunk=8192)


def _v_it6(cfg):
    import dataclasses

    # SSM-scan chunk remat: AD saves chunk-boundary states only (the Mamba/
    # RWKV analogue of the flash VJP).
    return dataclasses.replace(_v_it3(cfg), chunk_scan_remat=True)


# §Perf iteration ladder (all semantics-preserving; EXPERIMENTS.md §Perf)
VARIANTS = {
    "baseline": lambda cfg: cfg,
    "it1_flashvjp_padheads": _v_it1,
    "it2_losstable": _v_it2,
    "it3_losschunks": _v_it3,
    "it4_splitkv": _v_it3,  # + decode_split_kv, applied per-cell below
    "it5_decode_ws": _v_it3,  # + weight-stationary decode layout
    "it6_ssm_remat": _v_it6,  # + chunk-body remat in mamba/rwkv scans (measured
    # neutral on CPU lowering — EXPERIMENTS.md §Perf cell 4; kept as a variant)
    "optimized": _v_it3,
}
# variants that enable the shard_map split-KV decode combine (only meaningful
# on decode cells whose rules seq-sharded the cache over 'model')
_SPLIT_KV_VARIANTS = {"it4_splitkv", "it5_decode_ws", "optimized"}
# variants that use the weight-stationary decode layout (decode cells only)
_WS_DECODE_VARIANTS = {"it5_decode_ws", "optimized"}


def optimized(cfg):
    """The beyond-paper §Perf bundle (semantics-preserving, see EXPERIMENTS.md)."""
    return _v_it3(cfg)


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    extra_rules: Optional[dict] = None,
    opt: bool = False,
    variant: Optional[str] = None,
):
    """Build and lower the step for one (arch, shape) cell on ``mesh``.

    Returns (lowered, step_kind, abstract_args).
    """
    cfg = get_config(arch)
    if variant:
        cfg = VARIANTS[variant](cfg)
    elif opt:
        cfg = optimized(cfg)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        raise SkipCell(why)
    # Weight-stationary decode pays only when the per-token FSDP weight
    # gathers dominate: huge-param archs (jamba's 398B ⇒ 7.7 GB gathered per
    # generated token) or attention-free archs (no KV-cache read to amplify).
    # Measured both ways in EXPERIMENTS.md §Perf — this gate is the layout
    # cost-model ("match the component to the workload", the Adaptyst story).
    def _ws_pays(c) -> bool:
        from repro.models import lm as _lm
        from repro.utils.tree import tree_size_bytes

        if not c.uses_attention:
            return True
        return tree_size_bytes(_lm.abstract_params(c)) > 300e9

    weight_stationary = (
        (opt or variant in _WS_DECODE_VARIANTS)
        and shape.kind == "decode"
        and shape.global_batch >= mesh.shape.get("data", 1)  # batch=1: nothing to trade
        and _ws_pays(cfg)
    )
    rules = shd.rules_for_shape(
        shape.kind,
        global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        mesh=mesh,
        n_kv_heads=cfg.n_kv_heads,
        weight_stationary=weight_stationary,
    )
    if extra_rules:
        rules = rules.with_overrides(**extra_rules)
    # the shard_map split-KV combine is co-designed with the weight-stationary
    # cache layout; with the standard layout XLA's own partial-softmax handling
    # measured equal-or-better (EXPERIMENTS.md §Perf it4).
    wants_split = weight_stationary and (opt or variant in _SPLIT_KV_VARIANTS)
    cache_seq_assign = rules.act.get("cache_seq")
    if wants_split and shape.kind == "decode" and cache_seq_assign:
        import dataclasses

        seq_axes = (
            (cache_seq_assign,)
            if isinstance(cache_seq_assign, str)
            else tuple(cache_seq_assign)
        )
        batch_assign = rules.act.get("batch")
        batch_axes = (
            ()
            if batch_assign is None
            else ((batch_assign,) if isinstance(batch_assign, str) else tuple(batch_assign))
        )
        cfg = dataclasses.replace(
            cfg,
            decode_split_kv=True,
            decode_seq_axes=seq_axes,
            decode_batch_axes=batch_axes,
        )
    batch_abs = inputs_mod.input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = TrainConfig()
        step = make_train_step(cfg, tcfg)
        state_abs = abstract_train_state(cfg, tcfg)
        state_shd = _shardings(train_state_axes(cfg), state_abs, rules.param, mesh)
        batch_axes = {k: "batch,seq" for k in ("tokens", "labels")}
        if "frontend_embed" in batch_abs:
            batch_axes["frontend_embed"] = "batch,seq,embed"
        batch_shd = _shardings(batch_axes, batch_abs, rules.act, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(state_shd, batch_shd),
            out_shardings=(state_shd, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_abs, batch_abs)
        return lowered, "train_step", (state_abs, batch_abs)

    params_abs = lm.abstract_params(cfg)
    params_shd = _shardings(lm.param_axes(cfg), params_abs, rules.param, mesh)

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            return lm.prefill(
                params, cfg, batch["tokens"], batch.get("frontend_embed")
            )

        batch_axes = {"tokens": "batch,seq"}
        if "frontend_embed" in batch_abs:
            batch_axes["frontend_embed"] = "batch,seq,embed"
        batch_shd = _shardings(batch_axes, batch_abs, rules.act, mesh)
        jitted = jax.jit(
            prefill_step, in_shardings=(params_shd, batch_shd), out_shardings=None
        )
        lowered = jitted.lower(params_abs, batch_abs)
        return lowered, "prefill_step", (params_abs, batch_abs)

    # decode
    caches_abs = inputs_mod.abstract_decode_caches(cfg, SHAPES[shape_name])
    caches_shd = _shardings(lm.cache_axes(cfg), caches_abs, rules.act, mesh)
    batch_axes = {"tokens": "batch", "cur_pos": "batch"}
    if "frontend_embed" in batch_abs:
        batch_axes["frontend_embed"] = "batch,seq,embed"
    batch_shd = _shardings(batch_axes, batch_abs, rules.act, mesh)

    def serve_step(params, batch, caches):
        return lm.decode_step(
            params,
            cfg,
            batch["tokens"],
            batch["cur_pos"],
            caches,
            batch.get("frontend_embed"),
        )

    jitted = jax.jit(
        serve_step,
        in_shardings=(params_shd, batch_shd, caches_shd),
        out_shardings=(None, caches_shd),
        donate_argnums=(2,),
    )
    lowered = jitted.lower(params_abs, batch_abs, caches_abs)
    return lowered, "serve_step", (params_abs, batch_abs, caches_abs)


class SkipCell(Exception):
    pass


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    opt: bool = False,
    variant: Optional[str] = None,
) -> dict[str, Any]:
    """Lower + compile + analyse one cell.  Returns the record for §Dry-run."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_devices": mesh.devices.size,
        "variant": variant or ("optimized" if opt else "baseline"),
    }
    try:
        with mesh:
            lowered, kind, _ = lower_cell(
                arch, shape_name, mesh, opt=opt, variant=variant
            )
            rec["step"] = kind
            t1 = time.time()
            compiled = lowered.compile()
            rec["lower_s"] = round(t1 - t0, 1)
            rec["compile_s"] = round(time.time() - t1, 1)
            rec.update(analyze_compiled(lowered, compiled, mesh))
            # useful-work yardstick: MODEL_FLOPS vs compiled HLO FLOPs
            cfg = get_config(arch)
            mf = model_flops(cfg, SHAPES[shape_name], lm.abstract_params(cfg))
            rec["model_flops_global"] = mf
            hlo_global = rec["hlo_flops_per_dev"] * mesh.devices.size
            rec["useful_flops_ratio"] = round(mf / hlo_global, 4) if hlo_global else None
            # roofline fraction: ideal compute time / bound step time
            t_ideal = mf / (mesh.devices.size * 197e12)
            rec["t_model_ideal_s"] = t_ideal
            rec["roofline_fraction"] = round(
                t_ideal / rec["step_time_bound_s"], 4
            ) if rec["step_time_bound_s"] else None
            rec["status"] = "ok"
    except SkipCell as e:
        rec["status"] = "skip"
        rec["reason"] = str(e)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true", help="lower the §Perf-optimized variant")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS),
                    help="specific §Perf iteration to lower")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            try:
                rec = run_cell(
                    arch, shape, multi_pod=args.multi_pod, opt=args.opt,
                    variant=args.variant,
                )
            except Exception as e:  # a failure here is a bug in the system
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "status": "FAIL",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc(limit=6),
                }
                n_fail += 1
            print(json.dumps(rec, default=str))
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec, default=str) + "\n")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
