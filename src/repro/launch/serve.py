"""Serving driver: continuous-batching engine over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \\
      --requests 12 --max-new 16

Observability (repro.trace): --trace-out t.json snapshots the whole run —
events, dispatch decisions, measured profiles, chip + git metadata — for
`python -m repro.trace {report,export,diff}`; --trace-dir D streams events
durably as rotated JSONL segments while the server runs (a crash loses at
most the open segment; `python -m repro.trace compact D` recovers);
--profile-in warm-starts the profiled dispatcher from a previous session
(skips exploration; entries stamped with a different git SHA or chip are
aged out first); --profile-out writes the bare ProfileStore for the next run.

Fleet mode (repro.fleet): --fleet <url|dir> pulls the best matching profile
snapshot at startup (exact (git SHA, chip) match, falling back through
chip-only to nothing — stale-stamped entries re-explore), pushes measured
deltas at shutdown, and — with --trace-dir — at every streaming rotation, so
a long-lived server continuously feeds the central store.

Live metrics (repro.metrics): --metrics-port P scrapes Prometheus text at
http://127.0.0.1:P/metrics while the server runs; --trace-overhead-budget-pct
B starts the adaptive controller, which self-measures record-path overhead
and duty-cycles span capture to keep it under B% (0 = always-on: measure but
never shed).  Either flag activates the controller; metric snapshots land in
--trace-dir at every rotation and in the final JSON under "metrics".

Live device profiling (repro.trace.liveprof): --jax-profile DIR runs
jax.profiler capture in duty-cycled windows under a second, device-specific
budget loop sharing --trace-overhead-budget-pct (budget 0 = one calibration
window then measure-only); each closed window is parsed, span-aligned and
merged into the live trace/stream, and feeds repro_device_* series on
/metrics.  --jax-profile-backend synthetic exercises the same path with no
accelerator (CI); on CPU-only jax the real backend degrades gracefully with
one warning.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.dispatch import DispatchConfig, Dispatcher
from repro.models import lm
from repro.serving.engine import Engine, ServeConfig
from repro.trace import (
    Session,
    StreamingSession,
    TraceCollector,
    age_out_profiles,
    load_profile_stores,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--dispatch", choices=("off", "static", "roofline", "profiled"), default="off",
        help="profile-guided backend placement for prefill/decode (repro.dispatch)",
    )
    ap.add_argument("--dispatch-backend", default="chunked",
                    help="backend pinned by --dispatch static")
    ap.add_argument("--tune", choices=("off", "cached", "sweep"), default="off",
                    help="kernel autotuning (repro.tune): cached applies "
                         "winners already in the profile store (e.g. fleet-"
                         "pulled) with zero sweep cost; sweep measures "
                         "missing design-space points first")
    ap.add_argument("--tune-ops", default=None, metavar="OP[,OP]",
                    help="restrict --tune sweep to these ops")
    ap.add_argument("--tune-mode", choices=("real", "interpret", "synthetic"),
                    default="interpret",
                    help="sweep measurement mode (synthetic = model-only, CI)")
    ap.add_argument("--tune-workers", type=int, default=0, metavar="N",
                    help="sweep worker processes (0 = in-process)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a repro.trace session snapshot of this run")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="stream events durably as rotated JSONL segments "
                         "(crash loses at most the open segment; recover with "
                         "`python -m repro.trace compact DIR`)")
    ap.add_argument("--trace-rotate", type=int, default=2048, metavar="N",
                    help="events per streaming segment before rotation+fsync")
    ap.add_argument("--trace-rotate-keep", type=int, default=None, metavar="N",
                    help="segment retention: delete the oldest closed segments "
                         "past N so --trace-dir stays bounded on long runs")
    ap.add_argument("--fleet", default=None, metavar="URL|DIR",
                    help="central profile service (repro.fleet): pull matching "
                         "profiles at startup, push measured deltas at "
                         "shutdown and every streaming rotation")
    ap.add_argument("--fleet-token", default=None, metavar="TOKEN",
                    help="bearer token for a --token-protected fleet daemon")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="trace ring-buffer capacity (events); evictions are counted")
    ap.add_argument("--profile-in", action="append", default=None, metavar="PATH",
                    help="warm-start dispatch profiles from a session/store JSON "
                         "(repeatable; multiple files are merged)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write the measured ProfileStore for the next run")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus /metrics on this port while the "
                         "run is live (0 picks a free port)")
    ap.add_argument("--trace-overhead-budget-pct", type=float, default=None,
                    metavar="PCT",
                    help="adaptive tracing: duty-cycle span capture to keep "
                         "self-measured record-path overhead under PCT%% "
                         "(0 = always-on: measure, never shed; default 5 "
                         "when --metrics-port is given)")
    ap.add_argument("--ready-file", default=None, metavar="PATH",
                    help="announce the /metrics URL here once the listener "
                         "is up (requires --metrics-port; shared handshake "
                         "with repro.fleet serve and repro.router)")
    ap.add_argument("--metrics-linger-s", type=float, default=0.0, metavar="S",
                    help="keep the /metrics listener up S seconds after the "
                         "run completes (scrape windows for CI/cron)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="live device profiling: duty-cycled jax.profiler "
                         "capture windows dumped under DIR, parsed and merged "
                         "into the live trace under the overhead budget")
    ap.add_argument("--jax-profile-backend", default="auto",
                    choices=("auto", "jax", "synthetic"),
                    help="profiler backend: jax.profiler (auto/jax; degrades "
                         "gracefully without one) or the synthetic CI stub")
    ap.add_argument("--jax-profile-period-s", type=float, default=2.0,
                    metavar="S", help="device capture window period (on+off)")
    args = ap.parse_args()
    if args.fleet and args.dispatch == "off":
        # a fleet-less run would silently neither warm-start nor push
        ap.error("--fleet requires --dispatch (static|roofline|profiled)")
    if args.tune != "off" and args.dispatch == "off":
        # tune winners live in the dispatcher's profile store
        ap.error("--tune requires --dispatch (static|roofline|profiled)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, key)
    log = TraceCollector(capacity=args.trace_capacity)
    # metrics plane: always attached (near-zero cost, exact counts even under
    # shedding); the controller only runs when explicitly asked for, so plain
    # traced runs keep today's always-on capture behaviour
    from repro.metrics import (
        DEFAULT_BUDGET_PCT,
        AdaptiveController,
        MetricsPlane,
        serve_metrics,
    )

    plane = MetricsPlane(log)
    controller = mserver = None
    if args.metrics_port is not None or args.trace_overhead_budget_pct is not None:
        budget = (DEFAULT_BUDGET_PCT if args.trace_overhead_budget_pct is None
                  else args.trace_overhead_budget_pct)
        controller = AdaptiveController(log, plane.registry,
                                        budget_pct=budget).start()
    if args.metrics_port is not None:
        mserver = serve_metrics(plane, port=args.metrics_port)
        import sys

        print(f"metrics: {mserver.url}/metrics", file=sys.stderr)
        if args.ready_file:
            from repro.utils.ready import write_ready_file

            write_ready_file(args.ready_file, mserver.url)
    elif args.ready_file:
        ap.error("--ready-file requires --metrics-port (nothing to announce)")
    prof = None
    if args.jax_profile:
        from repro.trace.liveprof import LiveDeviceProfiler

        prof = LiveDeviceProfiler(
            log, args.jax_profile,
            registry=plane.registry,
            backend=args.jax_profile_backend,
            budget_pct=(DEFAULT_BUDGET_PCT
                        if args.trace_overhead_budget_pct is None
                        else args.trace_overhead_budget_pct),
            period_s=args.jax_profile_period_s,
        )
    dispatcher = None
    aged = []
    if args.dispatch != "off":
        store = load_profile_stores(args.profile_in) if args.profile_in else None
        dispatcher = Dispatcher(
            DispatchConfig(policy=args.dispatch, static_backend=args.dispatch_backend),
            log=log,
            store=store,
        )
        if args.profile_in:
            aged = age_out_profiles(dispatcher.store, dispatcher.chip.name)
    fleet_rec = pusher = None
    run_meta = {"driver": "serve", "arch": cfg.name, "requests": args.requests}
    if args.fleet and dispatcher is not None:
        from repro.fleet import warm_start_from_fleet

        fleet_rec, pusher = warm_start_from_fleet(args.fleet, dispatcher,
                                                  token=args.fleet_token)
        # recorded in session/manifest metadata: push-profiles refuses to
        # re-push artifacts of runs that already fed a fleet live
        run_meta["fleet"] = args.fleet
    tune_rec = None
    if args.tune != "off" and dispatcher is not None:
        # after the fleet pull (pulled config points make sweep points warm
        # — a fed fleet means sweep_points == 0) and before the engine
        # compiles its variants (winners must be installed before jit traces
        # them); sweep samples land in dispatcher.store, so the pusher
        # delta-pushes tuned winners at shutdown like any other measurement
        from repro.tune import driver_tune

        tune_rec = driver_tune(
            args.tune, dispatcher, log,
            ops_filter=args.tune_ops.split(",") if args.tune_ops else None,
            mode=args.tune_mode, workers=args.tune_workers,
        )
    stream = None
    if args.trace_dir:
        stream = StreamingSession(
            args.trace_dir,
            rotate_events=args.trace_rotate,
            max_segments=args.trace_rotate_keep,
            meta=run_meta,
            store_provider=(lambda: dispatcher.store) if dispatcher is not None else None,
            fleet_push=pusher.push if pusher is not None else None,
            metrics_provider=plane.snapshot,
            device_provider=prof.snapshot if prof is not None else None,
        ).attach(log)
    eng = Engine(
        cfg,
        params,
        ServeConfig(
            max_batch=args.max_batch,
            max_seq=args.max_seq,
            temperature=args.temperature,
            seed=args.seed,
        ),
        log=log,
        dispatcher=dispatcher,
        metrics=plane.registry,
    )
    rng = np.random.default_rng(args.seed)
    if prof is not None:
        prof.start()
    t0 = time.time()
    # root span of the whole run: every request (and transitively every
    # prefill/dispatch) nests under it in report --tree and the exporters
    with log.lifecycle("serve_run", {"arch": cfg.name, "requests": args.requests}):
        for _ in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size, args.prompt_len).tolist()
            eng.submit(prompt, max_new=args.max_new)
        results = eng.run_to_completion()
    wall = time.time() - t0
    if prof is not None:
        prof.stop()  # force-closes the open window: short runs still merge
    total_new = sum(len(v) for v in results.values())
    durations = log.durations("prefill")
    rec = {
        "arch": cfg.name,
        "requests": len(results),
        "generated_tokens": total_new,
        "tokens_per_s": round(total_new / wall, 1),
        "mean_prefill_ms": round(1e3 * float(np.mean(durations)), 2) if durations else None,
        "wall_s": round(wall, 2),
        "sample": results[min(results)][:8],
    }
    if dispatcher is not None:
        rec["dispatch"] = dispatcher.summary()
        rec["dispatch_events"] = len(log.events(kind="dispatch"))
        if args.profile_in:
            rec["profile_in"] = args.profile_in
            rec["profile_aged_out"] = len(aged)
    if tune_rec is not None:
        rec["tune"] = tune_rec
    if controller is not None:
        controller.stop()  # final overhead reading lands in the gauges
        rec["trace_controller"] = controller.snapshot()
    if prof is not None:
        rec["device_capture"] = prof.snapshot()
        run_meta["device_capture"] = rec["device_capture"]
    rec["metrics"] = plane.summary()
    trace_stats = log.stats()  # stats() resolves spans; compute once
    rec["trace"] = trace_stats
    if stream is not None:
        rec["trace_dir"] = stream.close(stats=trace_stats)
    if pusher is not None:
        final = pusher.push()  # remaining delta (no-op if a rotation covered it)
        fleet_rec["push"] = {"pushed_samples": pusher.pushed_samples}
        if "error" in final:
            fleet_rec["push"]["error"] = final["error"]
    if fleet_rec is not None:
        rec["fleet"] = fleet_rec
    if args.trace_out:
        sess = Session.capture(log, dispatcher=dispatcher,
                               meta={**run_meta, "metrics": plane.snapshot(),
                                     "drops": log.drop_counters()},
                               collector_stats=trace_stats)
        rec["trace_out"] = sess.save(args.trace_out)
    if args.profile_out and dispatcher is not None:
        doc = json.loads(dispatcher.store.to_json())
        if args.fleet:
            # marks the artifact as already fed to a fleet live, so
            # push-profiles refuses to double-count it later
            doc["fleet"] = args.fleet
        with open(args.profile_out, "w") as f:
            json.dump(doc, f, indent=1)
        rec["profile_out"] = args.profile_out
    print(json.dumps(rec), flush=True)
    if mserver is not None:
        if args.metrics_linger_s > 0:
            # the run JSON is already out (flushed): scrapers poll for it,
            # then hit /metrics while we linger
            time.sleep(args.metrics_linger_s)
        mserver.stop()


if __name__ == "__main__":
    main()
