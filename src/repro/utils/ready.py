"""Ready-file + ``--port 0`` startup handshake, shared by every daemon CLI.

The pattern: a server binds port 0 (the OS picks a free port), then announces
the bound URL by atomically writing a small *ready file*; whoever spawned it
(a CI script, the router's ReplicaManager, a test) polls for that file
instead of guessing ports or parsing logs.  One writer helper and one waiter
helper, so ``repro.fleet serve``, ``repro.router`` and its replicas — and
any future daemon — all speak the same handshake.

The payload is a single line of text (a bare URL) or a JSON object for
daemons that need to announce more than a URL (the router replicas report
pid/chip/git SHA too).  ``wait_for_ready_file`` returns the raw text;
``read_ready_info`` parses either form into a dict with at least ``url``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Optional

from repro.utils.io import atomic_write


def write_ready_file(path: str, payload: Any) -> None:
    """Announce readiness: atomically write the URL (str) or info (dict).

    Atomic write-then-rename means a polling reader never sees a torn file —
    the file either does not exist yet or carries the complete payload.
    """
    text = payload if isinstance(payload, str) else json.dumps(payload)
    atomic_write(path, text)


def read_ready_info(path: str) -> dict[str, Any]:
    """Parse a ready file into ``{"url": ..., ...}`` (bare-URL or JSON form)."""
    with open(path) as f:
        text = f.read().strip()
    if text.startswith("{"):
        info = json.loads(text)
        if not isinstance(info, dict) or "url" not in info:
            raise ValueError(f"ready file {path} carries no 'url': {text[:120]!r}")
        return info
    return {"url": text}


def wait_for_ready_file(
    path: str,
    timeout_s: float = 60.0,
    *,
    poll_s: float = 0.05,
    proc: Optional[Any] = None,
) -> str:
    """Poll until the ready file appears; return its text.

    ``proc`` (a ``subprocess.Popen``) short-circuits the wait when the daemon
    died before announcing — the caller gets a ``RuntimeError`` immediately
    instead of burning the whole timeout against a corpse.
    """
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if os.path.exists(path):
            with open(path) as f:
                text = f.read().strip()
            if text:  # atomic_write means non-empty == complete
                return text
        if proc is not None and proc.poll() is not None:
            raise RuntimeError(
                f"process exited (rc={proc.returncode}) before writing "
                f"ready file {path}")
        time.sleep(poll_s)
    raise TimeoutError(f"ready file {path} did not appear within {timeout_s}s")
