"""Shared filesystem helpers."""
from __future__ import annotations

import os


def atomic_write(path: str, text: str) -> None:
    """Write-then-rename with fsync: readers never see a torn file."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
