"""Shared helpers: filesystem, daemon handshake, pytree utilities.

The pytree helpers (:mod:`repro.utils.tree`) import jax; they are re-exported
lazily (PEP 562) so jax-free processes — the router front door, synthetic
replicas, the fleet CLI — can use :mod:`repro.utils.io` and
:mod:`repro.utils.ready` without paying (or requiring) a jax import.
"""
from repro.utils.io import atomic_write
from repro.utils.ready import read_ready_info, wait_for_ready_file, write_ready_file

_TREE_EXPORTS = frozenset({
    "assert_no_nans",
    "tree_cast",
    "tree_flatten_with_paths",
    "tree_map_with_path",
    "tree_param_count",
    "tree_size_bytes",
    "tree_zeros_like",
})

__all__ = [
    "atomic_write",
    "read_ready_info",
    "wait_for_ready_file",
    "write_ready_file",
    *sorted(_TREE_EXPORTS),
]


def __getattr__(name: str):
    if name in _TREE_EXPORTS:
        from repro.utils import tree

        return getattr(tree, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
