from repro.utils.io import atomic_write
from repro.utils.tree import (
    assert_no_nans,
    tree_cast,
    tree_flatten_with_paths,
    tree_map_with_path,
    tree_param_count,
    tree_size_bytes,
    tree_zeros_like,
)

__all__ = [
    "assert_no_nans",
    "atomic_write",
    "tree_cast",
    "tree_flatten_with_paths",
    "tree_map_with_path",
    "tree_param_count",
    "tree_size_bytes",
    "tree_zeros_like",
]
