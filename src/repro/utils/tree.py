"""Small pytree utilities shared across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_size_bytes(tree: PyTree) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree.leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape, dtype=np.int64)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: PyTree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape, dtype=np.int64))
    return total


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_flatten_with_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """Flatten to (dotted-path, leaf) pairs with deterministic ordering."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((_path_str(path), leaf))
    return out


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def assert_no_nans(tree: PyTree, where: str = "") -> None:
    for path, leaf in tree_flatten_with_paths(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if bool(jnp.any(jnp.isnan(leaf))):
                raise AssertionError(f"NaN at {where}:{path}")
